"""Geo-distributed fleet: three regions, one global router, one regional
cooling failure.

Three regions with divergent weather — a hot-climate ``gulf``, a mild
``plains``, a cold ``fjord`` — each run their own TAPAS control plane
(placement / routing / instance configuration) over their own cluster
physics.  At hour 3 the gulf region suffers a thermal emergency (an AHU
loss plus DC-level cooling strain) in the middle of a heat wave and a
fleet-wide demand surge.

The drill runs twice with the per-region control planes held fixed:

* ``latency``  — ``LatencyOnlyRouter``: the per-region-greedy baseline.
  Every region serves its own demand; the failing region fights alone.
* ``global``   — ``GlobalTapasRouter``: ``server_risk`` lifted to region
  granularity.  Demand is steered off the failing region toward cooler
  regions (paying the WAN-latency goodput penalty), and sustained
  emergency risk drains whole VMs cross-region.

The printed trace shows routing visibly shift during the failure window,
and the run asserts the global router finishes the drill with fewer
throttle events than the per-region-greedy baseline.

    PYTHONPATH=src python examples/geo_fleet.py
"""
import numpy as np

from repro.core.datacenter import DCConfig
from repro.core.fleet import (FleetConfig, FleetSim, GlobalTapasRouter,
                              LatencyOnlyRouter, RegionSpec)
from repro.core.scenario import (DemandSurge, FailureEvent, Scenario,
                                 WeatherShift)
from repro.core.simulator import TAPAS


def make_fleet(fleet_policy, seed: int = 0) -> FleetSim:
    """The drill: 3 regions, gulf loses cooling mid-heat-wave.  Also the
    workload ``benchmarks/bench_fleet.py`` records and CI gates on."""
    def dc(climate):
        return DCConfig(n_rows=4, racks_per_row=4, servers_per_rack=4,
                        region=climate)

    regions = (
        RegionSpec("gulf", dc=dc("hot"), wan_rtt_ms=10.0, power_price_scale=1.2),
        RegionSpec("plains", dc=dc("mild"), wan_rtt_ms=25.0),
        RegionSpec("fjord", dc=dc("cold"), wan_rtt_ms=45.0,
                   power_price_scale=0.7),
    )
    scenario = Scenario((
        # hour 3-10: gulf loses an AHU + DC cooling strain, mid-heat-wave
        FailureEvent(kind="thermal", start_h=3.0, end_h=10.0, target=0,
                     region="gulf"),
        FailureEvent(kind="cooling", start_h=3.0, end_h=10.0, region="gulf"),
        WeatherShift(start_h=2.0, end_h=11.0, delta_c=12.0, region="gulf"),
        DemandSurge(start_h=3.0, end_h=9.0, scale=1.3),
    ))
    return FleetSim(FleetConfig(
        regions=regions, horizon_h=12.0, tick_min=10.0, seed=seed,
        policy=TAPAS, fleet=fleet_policy, scenario=scenario,
        occupancy=0.97, demand_scale=1.05))


def run_drill(label: str, fleet_policy, *, verbose: bool) -> dict:
    fs = make_fleet(fleet_policy)
    if verbose:
        print(f"  {'h':>5} {'gulf':>22} {'plains':>16} {'fjord':>16} "
              f"{'moved':>8}")
    prev_moved = 0.0
    while fs.tick < fs.ticks:
        st = fs.step()
        if verbose and fs.tick % 6 == 0:
            moved = fs._moved - prev_moved     # since the last printed row
            prev_moved = fs._moved
            cells = []
            for name in ("gulf", "plains", "fjord"):
                cs = st.regions[name]
                load = float(cs.saas_load[cs.kind == 2].sum())
                flag = "!" if st.emergency[name] else " "
                cells.append(f"risk={st.risk[name]:.2f}{flag} "
                             f"load={load:5.1f}")
            print(f"  {st.now_h:5.1f} {cells[0]:>22} {cells[1]:>16} "
                  f"{cells[2]:>16} {moved:8.1f}")
    res = fs.result()
    s = res.summary()
    print(f"{label:8s} throttle={s['throttle_events']:3d} "
          f"(per region { {n: r['thermal_events'] for n, r in s['regions'].items()} }) "
          f"unserved={s['unserved_frac']:.4f} quality={s['mean_quality']:.3f} "
          f"moved={s['moved_load']:.1f} migrations={s['migrations']}\n")
    return s


def main() -> None:
    print("== per-region-greedy baseline (LatencyOnlyRouter) ==")
    base = run_drill("latency", LatencyOnlyRouter, verbose=False)
    print("== global risk-weighted router (GlobalTapasRouter) ==")
    glob = run_drill("global", GlobalTapasRouter, verbose=True)

    # the routing shift must be real and must pay off in throttling
    assert glob["moved_load"] > 0.0, \
        "the global router steered nothing during a regional emergency"
    assert base["moved_load"] == 0.0
    assert glob["throttle_events"] < base["throttle_events"], (
        f"global router did not reduce throttling: "
        f"{glob['throttle_events']} vs {base['throttle_events']}")
    print(f"regional cooling failure: global router cut throttle events "
          f"{base['throttle_events']} -> {glob['throttle_events']} by "
          f"steering {glob['moved_load']:.0f} VM-ticks of load "
          f"(+{glob['migrations']} VM migrations) across regions")


if __name__ == "__main__":
    main()
