"""Train a ~100M-param model for a few hundred steps with checkpoint/restart
(the training end-to-end driver).

    PYTHONPATH=src python examples/train_small.py [--steps 300]

Uses a ~100M-parameter qwen3-family config (real vocab, 8 layers).  On this
CPU container a few hundred steps take a while; --steps 60 shows the same
loss curve shape.  Kill it mid-run and rerun: it resumes from the last
committed checkpoint.
"""
import argparse

from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()

    # ~100M params: 8L x d512 x ffn2048, 32k vocab
    out = train_main([
        "--arch", "qwen3-1.7b", "--smoke", "--steps", str(args.steps),
        "--batch", "16", "--seq", "128", "--lr", "1e-3",
        "--ckpt", args.ckpt, "--ckpt-every", "25",
    ])
    print(out)


if __name__ == "__main__":
    main()
