"""Fleet oversubscription planning + carbon/price-aware steering.

Two demonstrations of the fleet-level TCO claims (paper §4.4, Fig. 19/20),
both also recorded by ``benchmarks/bench_fleet_oversub.py`` and gated in
CI through ``scripts/check_bench.py``:

1. **Coordinated provisioning beats isolated provisioning.**  Two regions
   — a hot-climate ``ridge`` that suffers a UPS failover (rows derated to
   75% power) in the middle of a heat wave and a regional demand surge,
   and a cold ``lake`` — are sized by ``FleetOversubPlanner`` twice: each
   region alone, and jointly under the global router.  Alone, ridge must
   stop at the oversubscription ratio whose failure-window power capping
   blows the §5.3 budget; coordinated, the router drains ridge's SaaS
   demand cross-region during the emergency and the same region safely
   hosts strictly more servers on the same cooling/power envelopes.

2. **Cost-aware steering cuts the energy bill at unchanged goodput.**  A
   dirty/expensive ``coal`` region and a clean/cheap ``hydro`` region run
   the same workload twice: under the recorded ``GlobalTapasRouter``
   (thermal steering only) and with ``cost_aware_knobs()`` enabled, while
   a scripted ``PriceShock`` spikes coal's spot price mid-run.  The
   cost-aware fleet serves the same demand (goodput within 1%) while the
   blended price/carbon cost of the energy drops.

    PYTHONPATH=src python examples/fleet_oversub_planner.py
"""
from repro.core.datacenter import DCConfig
from repro.core.fleet import (FleetConfig, FleetKnobs, FleetSim,
                              GlobalTapasRouter, RegionSpec,
                              cost_aware_knobs)
from repro.core.oversubscribe import FleetOversubPlanner
from repro.core.scenario import (DemandSurge, FailureEvent, PriceShock,
                                 Scenario, WeatherShift)
from repro.core.simulator import TAPAS

#: carbon weight of the blended cost index the steering minimizes (and
#: the benchmark scores) — 0.5 prices money and carbon equally.
CARBON_WEIGHT = 0.5
#: ratio grid the planner searches (rack-aligned for racks_per_row=8).
RATIOS = (0.0, 0.125, 0.25, 0.375, 0.5)


def make_planner_fleet(seed: int = 0) -> FleetConfig:
    """The provisioning drill: ridge loses UPS redundancy mid-heat-wave.
    Also the workload ``benchmarks/bench_fleet_oversub.py`` records."""
    def dc(climate):
        return DCConfig(n_rows=2, racks_per_row=8, servers_per_rack=2,
                        region=climate)

    regions = (
        RegionSpec("ridge", dc=dc("hot"), wan_rtt_ms=8.0, power_price_scale=1.2),
        RegionSpec("lake", dc=dc("cold"), wan_rtt_ms=14.0, power_price_scale=0.7),
    )
    scenario = Scenario((
        # hours 7-11: ridge's UPS failover caps every row at 75% power,
        # in a heat wave, while regional demand surges
        FailureEvent(kind="ups", start_h=7.0, end_h=11.0, region="ridge"),
        WeatherShift(start_h=6.0, end_h=11.5, delta_c=8.0, region="ridge"),
        DemandSurge(start_h=7.0, end_h=10.0, scale=1.3, region="ridge"),
    ))
    # the steering threshold is tuned for the oversubscribed regime: the
    # near-limit power ramp keeps every densified region's risk elevated,
    # so the default 0.45 would veto every destination
    return FleetConfig(
        regions=regions, horizon_h=12.0, tick_min=15.0, seed=seed,
        policy=TAPAS, scenario=scenario, occupancy=0.92, demand_scale=0.95,
        fleet=lambda: GlobalTapasRouter(FleetKnobs(risk_threshold=0.7)))


def make_cost_fleet(fleet_policy, seed: int = 0) -> FleetSim:
    """The steering drill: dirty/expensive coal vs clean/cheap hydro, with
    a spot-price spike on coal mid-run."""
    def dc(climate):
        return DCConfig(n_rows=2, racks_per_row=4, servers_per_rack=2,
                        region=climate)

    regions = (
        RegionSpec("coal", dc=dc("mild"), wan_rtt_ms=8.0, power_price_scale=1.3,
                   carbon_scale=1.5),
        RegionSpec("hydro", dc=dc("cold"), wan_rtt_ms=14.0, power_price_scale=0.6,
                   carbon_scale=0.4),
    )
    scenario = Scenario((
        PriceShock(start_h=6.0, end_h=10.0, scale=1.6, region="coal"),
    ))
    return FleetSim(FleetConfig(
        regions=regions, horizon_h=12.0, tick_min=15.0, seed=seed,
        policy=TAPAS, scenario=scenario, occupancy=0.8, demand_scale=0.6,
        fleet=fleet_policy))


def run_planner(seed: int = 0) -> dict:
    planner = FleetOversubPlanner(make_planner_fleet(seed), ratios=RATIOS)
    plan = planner.plan()
    s = plan.summary()
    print(f"{'region':<8}{'isolated':>10}{'coordinated':>13}")
    for name in sorted(plan.isolated):
        print(f"{name:<8}{plan.isolated[name]:>10.1%}"
              f"{plan.coordinated[name]:>13.1%}")
    print(f"{'total':<8}{s['isolated_total']:>10.1%}"
          f"{s['coordinated_total']:>13.1%}   "
          f"({s['evaluations']} simulation runs)\n")
    return s


def run_cost_pair(seed: int = 0) -> tuple:
    out = {}
    for label, policy in (
            ("thermal-only", GlobalTapasRouter),
            ("cost-aware", lambda: GlobalTapasRouter(
                cost_aware_knobs(cost_shift_max=0.6)))):
        res = make_cost_fleet(policy, seed=seed).run()
        s = res.summary()
        out[label] = s | {"blended_cost": res.blended_cost(CARBON_WEIGHT)}
        print(f"{label:<13} blended={out[label]['blended_cost']:8.1f} "
              f"energy_cost={s['energy_cost']:8.1f} "
              f"carbon={s['carbon_kg']:8.1f} moved={s['moved_load']:6.1f} "
              f"unserved={s['unserved_frac']:.5f}")
    return out["thermal-only"], out["cost-aware"]


def main() -> None:
    print("== fleet oversubscription planning "
          "(regional UPS failure drill) ==")
    plan = run_planner()
    assert plan["coordinated_safe"]
    assert plan["coordinated_total"] > plan["isolated_total"], (
        f"fleet coordination admitted no extra oversubscription: "
        f"{plan['coordinated_total']} !> {plan['isolated_total']}")
    print(f"fleet-coordinated planning admits "
          f"{plan['coordinated_total'] - plan['isolated_total']:+.1%} "
          f"oversubscription over per-region planning — the global router "
          f"absorbs the scripted UPS failure cross-region\n")

    print("== carbon/price-aware steering (coal vs hydro, price shock) ==")
    base, cost = run_cost_pair()
    saving = 1.0 - cost["blended_cost"] / base["blended_cost"]
    goodput = (1.0 - cost["unserved_frac"]) / (1.0 - base["unserved_frac"])
    assert cost["moved_load"] > 0.0, "cost-aware steering never engaged"
    assert cost["blended_cost"] < base["blended_cost"], (
        f"cost-aware steering did not cut the blended energy cost: "
        f"{cost['blended_cost']:.1f} !< {base['blended_cost']:.1f}")
    assert goodput >= 0.99, f"goodput dropped more than 1%: {goodput:.4f}"
    print(f"cost-aware steering cut the blended energy cost by "
          f"{saving:.1%} (goodput ratio {goodput:.4f}) by moving "
          f"{cost['moved_load']:.0f} VM-ticks of load onto the "
          f"cheap/clean grid")


if __name__ == "__main__":
    main()
