"""Engine-in-the-loop: REAL serving engines as SaaS servers inside the
TAPAS cluster simulation.

The step-wise ``ClusterSim`` is driven tick-by-tick from the outside.  A
few ticks in, two of the placed SaaS servers get a real ``Engine`` bound
to them via ``EngineBackend``; from then on every TAPAS ``reconfigure()``
decision for those servers lands on actual engine knobs (``freq_scale`` /
``max_batch`` / ``set_variant``) and the engines' *measured* goodput is
reported back into ``ClusterState.measured_goodput`` — the paper's
Fig. 17 control loop with a live model in place of vLLM.

A scripted ``Scenario`` (thermal emergency + demand surge over hours 2–6)
pushes the backed servers' violation risk over the reconfigure threshold
mid-run, so the knob turns are observable in the printed trace.

    PYTHONPATH=src python examples/engine_in_the_loop.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import DCConfig
from repro.core.scenario import DemandSurge, FailureEvent, Scenario
from repro.core.simulator import TAPAS, ClusterSim, SimConfig
from repro.serving import Engine, EngineBackend, EngineSpec

N_BACKENDS = 2


def build_engine(seed: int) -> Engine:
    cfg = get_config("llama2-7b").smoke_config()
    small = cfg.replace(num_layers=1, d_ff=64, name="llama2-smaller")
    return EngineSpec(cfg, max_seq=96, n_slots=4, max_batch=4, seed=seed,
                      variants=(("small", small),)).build()


def main() -> None:
    dc = DCConfig(n_rows=2, racks_per_row=2, servers_per_rack=4,
                  region="hot")
    scenario = Scenario((
        FailureEvent(kind="thermal", start_h=2.0, end_h=6.0, target=0),
        DemandSurge(start_h=2.0, end_h=6.0, scale=1.4),
    ))
    sim = ClusterSim(SimConfig(dc=dc, horizon_h=8.0, tick_min=10.0, seed=1,
                               policy=TAPAS, occupancy=0.95,
                               demand_scale=1.0, scenario=scenario))

    # --- drive the sim until SaaS servers exist, then bind real engines ---
    backends: dict[int, EngineBackend] = {}
    while len(backends) < N_BACKENDS and sim.tick < sim.ticks:
        state = sim.step()
        saas = np.flatnonzero(state.kind == 2)
        if len(saas) >= N_BACKENDS and not backends:
            for i, srv in enumerate(saas[:N_BACKENDS]):
                b = EngineBackend(build_engine(i), seed=i,
                                  variant_for_size={"70b": "full",
                                                    "13b": "small",
                                                    "7b": "small"})
                sim.attach_backend(int(srv), b)
                backends[int(srv)] = b
    servers = sorted(backends)
    knobs0 = {s: (backends[s].engine.knobs.freq_scale,
                  backends[s].engine.knobs.max_batch,
                  backends[s].engine.knobs.variant) for s in servers}
    print(f"engines bound to servers {servers} (knobs: {knobs0})\n")
    hdr = " ".join(f"srv{s}: risk freq bat var   gp" for s in servers)
    print(f"{'h':>5} emerg  {hdr}")

    # --- continue the run with the engines in the loop --------------------
    while sim.tick < sim.ticks:
        state = sim.step()
        if sim.tick % 3:
            continue
        cells = []
        for s in servers:
            k = backends[s].engine.knobs
            cells.append(f"{state.risk[s]:10.2f} {k.freq_scale:.2f} "
                         f"{k.max_batch:>3} {k.variant[:4]:<4} "
                         f"{state.measured_goodput.get(s, 0.0):6.0f}")
        print(f"{state.now_h:5.1f} {str(state.emergency):<5} "
              + " ".join(cells))

    # --- verify the loop actually closed ----------------------------------
    applied = {s: backends[s].applied for s in servers}
    changed = {s: (backends[s].engine.knobs.freq_scale,
                   backends[s].engine.knobs.max_batch,
                   backends[s].engine.knobs.variant) != knobs0[s]
               or len(applied[s]) > 0 for s in servers}
    served = {s: len(backends[s].engine.stats.completed) for s in servers}
    print(f"\nconfigs applied per server (first is the attach-time sync): "
          f"{ {s: len(a) for s, a in applied.items()} }")
    print(f"requests completed per engine: {served}")
    print(f"final summary: { {k: round(float(v), 4) for k, v in sim.result().summary().items()} }")
    # beyond the initial attach-time sync, live reconfigure decisions must
    # have reached the engines and observably turned their knobs
    assert any(len(a) > 1 for a in applied.values()), \
        "no reconfigure decision reached an engine"
    assert all(changed.values()), "a bound engine saw no observable change"
    assert all(n > 0 for n in served.values()), "an engine served nothing"


if __name__ == "__main__":
    main()
