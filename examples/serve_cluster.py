"""End-to-end TAPAS mini-cluster: REAL serving engines under the TAPAS
control plane.

Four Engine instances (SaaS VMs on 4 'servers' of one row) serve live
requests through the thermal/power-aware router; the instance configurator
reacts to a simulated afternoon heat spike by trimming the hot server's
batch knob and, in an emergency, swapping it to the smaller model variant —
exactly the paper's Fig. 17 loop with a real model in place of vLLM.

    PYTHONPATH=src python examples/serve_cluster.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import Datacenter, DCConfig
from repro.core.router import TapasRouter
from repro.core.thermal import ThermalModel
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request

N_VMS = 4


def main() -> None:
    # --- real engines (one per VM) ---
    cfg = get_config("llama2-7b").smoke_config()
    small = cfg.replace(num_layers=1, d_ff=64, name="llama2-smaller")
    plan = local_plan(param_dtype=jnp.bfloat16)
    model = build_model(cfg, plan)
    model_small = build_model(small, plan)
    params = model.init(jax.random.PRNGKey(0))
    params_small = model_small.init(jax.random.PRNGKey(1))
    engines = []
    for v in range(N_VMS):
        e = Engine(model, params, max_seq=96, n_slots=4,
                   knobs=EngineKnobs(max_batch=4), paged=True, block_size=16)
        e.add_variant("small", model_small, params_small)
        engines.append(e)

    # --- physics for their servers (first 4 servers of row 0) ---
    dc = Datacenter(DCConfig(n_rows=2, racks_per_row=1, servers_per_rack=4))
    th = ThermalModel.calibrate(dc)
    router = TapasRouter()
    rng = np.random.default_rng(0)

    print(f"{'tick':>4} {'t_out':>6} {'risk':>24} {'load':>24} served")
    for tick in range(8):
        t_out = 26.0 + 2.0 * tick  # afternoon heat ramp
        inlet = np.asarray(th.inlet_temp(t_out, 0.7))[:N_VMS]
        u_max = np.asarray(th.max_util_for_temp(
            np.asarray(th.inlet_temp(t_out, 0.7)), th.gpu_limit - 3.0))[:N_VMS]
        risk = 1.0 / (1.0 + np.exp(-(np.asarray(th.gpu_temp(
            np.asarray(th.inlet_temp(t_out, 0.7)),
            np.ones((dc.n_servers, 8))))[:N_VMS].max(1) - th.gpu_limit) / 2.0))

        # TAPAS instance configuration: hot VMs trim batch; hottest swaps model
        for v, e in enumerate(engines):
            if risk[v] > 0.8 and e.knobs.variant != "small":
                e.set_variant("small")      # emergency: smaller model
            elif risk[v] > 0.5:
                e.knobs.max_batch = 2       # shave thermal output
            else:
                e.knobs.max_batch = 4

        # route this tick's requests by risk-aware weights
        n_req = int(rng.integers(4, 9))
        cap = np.asarray([u_max[v] * engines[v].knobs.max_batch
                          for v in range(N_VMS)])
        dec = router.route(float(n_req), cap, risk)
        served = 0
        for v, e in enumerate(engines):
            for _ in range(int(round(dec.load[v]))):
                e.submit(Request(
                    prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                    max_new_tokens=4, customer=f"c{rng.integers(0, 3)}"))
            before = len(e.stats.completed)
            for _ in range(6):
                e.step(now=float(tick))
            served += len(e.stats.completed) - before
        print(f"{tick:>4} {t_out:>6.1f} "
              f"{np.array2string(risk, precision=2):>24} "
              f"{np.array2string(dec.load, precision=1):>24} {served}")

    total = sum(len(e.stats.completed) for e in engines)
    variants = [e.knobs.variant for e in engines]
    util = [round(e.pool.utilization(), 2) for e in engines]
    print(f"\ncompleted {total} requests; final variants: {variants}; "
          f"paged-pool utilization: {util}")
    assert total > 0


if __name__ == "__main__":
    main()
