"""Fault-storm drill: engines that crash, sensors that lie, and a serving
tier that survives both.

A small cluster takes a cooling failure, and — mid-emergency — a fault
storm: one bound engine crashes for a stretch, another takes a NaN-logit
burst in its KV cache, and the cluster's derived telemetry goes stale
(``SensorDropout``) for the worst of it.  The drill runs three arms over
an identical workload:

* ``fault_free``  — the cooling emergency only (the goodput yardstick).
* ``recovery on`` — the storm with the full recovery stack: watchdog
  drains the crashed engine's work onto its sibling, the NaN guard
  quarantines the poisoned lane and re-queues the request on the
  recompute path, stale telemetry is risk-bumped, and the degradation
  ladder walks each backend down (and back up) around the emergency.
* ``recovery off`` — the same storm with ``faults.recovery_off()``: the
  crash drops its in-flight and queued work, corruption goes unguarded,
  and the frozen sensors are trusted verbatim.

Every request the backends ever issue is kept in a ledger and audited
after a drained run (``faults.audit_requests``): with recovery on, *zero*
requests may vanish — every one must end accepted, timed-out, or
rejected.  ``benchmarks/bench_resilience.py`` records the same drill's
goodput numbers, so the CI example smoke and the recorded bench can never
drift apart.

    PYTHONPATH=src python examples/fault_storm.py
"""
import numpy as np

from repro.configs import get_config
from repro.core.datacenter import DCConfig
from repro.core.faults import (DegradationLadder, EngineFault,
                               ResilienceKnobs, SensorDropout,
                               audit_requests, recovery_off)
from repro.core.scenario import FailureEvent, Scenario
from repro.core.simulator import TAPAS, ClusterSim, SimConfig
from repro.serving import Engine, EngineBackend, EngineSpec

#: drill clock (hours): cooling fails mid-run; the storm lands inside it
HORIZON_H, TICK_MIN = 2.0, 5.0
COOLING = (0.8, 1.2)
CRASH = (0.9, 1.1)          # first backed server dies for ~2 ticks
NAN_BURST = (1.0, 1.1)      # second backed server's KV goes NaN
DROPOUT = (0.8, 1.3)        # telemetry frozen past the emergency's end


def drill_spec() -> EngineSpec:
    cfg = get_config("llama2-7b").smoke_config()
    return EngineSpec(cfg, max_seq=96, n_slots=4, max_batch=4, block_size=8)


def _make_engine(share: Engine) -> Engine:
    # every arm's engines alias the one weight copy held by ``share``
    return drill_spec().build(share=share)


def _sim(dc: DCConfig, seed: int, scenario: Scenario,
         knobs: ResilienceKnobs | None) -> ClusterSim:
    return ClusterSim(SimConfig(
        dc=dc, horizon_h=HORIZON_H, tick_min=TICK_MIN, seed=seed,
        policy=TAPAS, occupancy=0.95, demand_scale=1.0,
        scenario=scenario, resilience=knobs))


def run_drill(*, seed: int, storm: bool, knobs: ResilienceKnobs | None,
              share: Engine) -> dict:
    """One arm of the drill; returns the audited outcome summary.

    The workload is identical across arms for a given ``seed`` (the
    backends' request streams are seeded per server), so accepted-token
    goodput is directly comparable between them.
    """
    dc = DCConfig(n_rows=2, racks_per_row=2, servers_per_rack=4,
                  region="hot")
    # probe pass: find the tick at which >= 2 SaaS servers exist, so
    # every arm binds engines to the same servers at the same tick
    probe = _sim(dc, seed, Scenario(), None)
    attach_tick, saas = None, []
    while probe.tick < probe.ticks:
        st = probe.step()
        saas = [int(s) for s in np.flatnonzero(st.kind == 2)]
        if len(saas) >= 2:
            attach_tick = probe.tick
            break
    if attach_tick is None:
        raise RuntimeError("drill datacenter never placed 2 SaaS servers")

    events = [FailureEvent(kind="cooling", start_h=COOLING[0],
                           end_h=COOLING[1], target=0)]
    if storm:
        events += [
            EngineFault(kind="crash", start_h=CRASH[0], end_h=CRASH[1],
                        server=saas[0]),
            EngineFault(kind="nan_burst", start_h=NAN_BURST[0],
                        end_h=NAN_BURST[1], server=saas[1]),
            SensorDropout(start_h=DROPOUT[0], end_h=DROPOUT[1]),
        ]
    res = knobs if knobs is not None else ResilienceKnobs()
    sim = _sim(dc, seed, Scenario(tuple(events)), res)
    backends: dict[int, EngineBackend] = {}
    max_age = 0
    while sim.tick < sim.ticks:
        st = sim.step()
        max_age = max(max_age, st.telemetry_age_ticks)
        if sim.tick == attach_tick and not backends:
            for srv in saas[:2]:
                bk = EngineBackend(
                    _make_engine(share), seed=srv,
                    max_new_tokens=8, steps_per_tick=5,
                    ladder=DegradationLadder() if res.ladder else None,
                    deadline_ms=3_600_000.0)
                sim.attach_backend(srv, bk)
                backends[srv] = bk
    for bk in backends.values():
        bk.drain(now_h=float(sim.t_h[-1]) + TICK_MIN / 60.0)

    issued = [r for bk in backends.values() for r in bk.issued]
    audit = audit_requests(issued)
    engines = [bk.engine for bk in backends.values()]
    return {
        "goodput_tokens": audit["accepted_tokens"],
        "outcomes": audit["outcomes"],
        "lost_requests": len(audit["lost"]),
        "issued": audit["total"],
        "crashes": sum(e.stats.crashes for e in engines),
        "quarantined": sum(e.stats.quarantined for e in engines),
        "retried": sum(e.stats.retried for e in engines),
        "timed_out": sum(e.stats.timed_out for e in engines),
        "dropped": sum(len(bk.dropped) for bk in backends.values()),
        "watchdog_drains": sim.watchdog_drains,
        "ladder_walks": sum(bk.ladder.walks for bk in backends.values()
                            if bk.ladder is not None),
        "max_telemetry_age": max_age,
    }


def main() -> None:
    share = drill_spec().build()
    print("fault-storm drill: cooling failure + engine crash + NaN burst "
          "+ sensor dropout\n")
    arms = {}
    for label, storm, knobs in (("fault_free", False, None),
                                ("recovery_on", True, None),
                                ("recovery_off", True, recovery_off())):
        arms[label] = r = run_drill(seed=0, storm=storm, knobs=knobs,
                                    share=share)
        print(f"{label:13s} goodput={r['goodput_tokens']:5d} tok  "
              f"outcomes={r['outcomes']}  lost={r['lost_requests']}  "
              f"crashes={r['crashes']} quarantined={r['quarantined']} "
              f"watchdog={r['watchdog_drains']} ladder={r['ladder_walks']}")

    free, on, off = (arms[k] for k in ("fault_free", "recovery_on",
                                       "recovery_off"))
    ratio_on = on["goodput_tokens"] / max(free["goodput_tokens"], 1)
    ratio_off = off["goodput_tokens"] / max(free["goodput_tokens"], 1)
    print(f"\ngoodput vs fault-free: recovery on {ratio_on:.3f}, "
          f"recovery off {ratio_off:.3f}")

    # the recovery stack's contract: nothing vanishes, the storm barely
    # dents goodput, and turning recovery off demonstrably loses work
    assert on["lost_requests"] == 0, "recovery-on run lost requests"
    assert on["crashes"] >= 1 and on["quarantined"] >= 1
    assert on["watchdog_drains"] >= 1 and on["max_telemetry_age"] > 0
    assert on["ladder_walks"] >= 1
    assert ratio_on >= 0.9, f"storm cost too much goodput: {ratio_on:.3f}"
    assert off["lost_requests"] + off["dropped"] > 0, \
        "recovery-off lost nothing — the storm has no teeth"
    assert ratio_off < ratio_on, "recovery machinery made nothing better"
    print("fault-storm drill OK")


if __name__ == "__main__":
    main()
