"""Quickstart: build an assigned architecture, train it a little, serve it.

    PYTHONPATH=src python examples/quickstart.py [arch]

Everything runs at smoke scale on CPU; the identical code paths run the
full configs on a TPU pod via launch/ (see README).
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model, local_plan
from repro.serving import Engine, EngineKnobs, Request
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_step import init_opt_state, make_train_step


def main(arch: str = "qwen3-1.7b") -> None:
    cfg = get_config(arch).smoke_config()
    print(f"== {arch} (reduced config: {cfg.num_layers}L d={cfg.d_model}) ==")
    model = build_model(cfg, local_plan(param_dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"params: {n_params:,}")

    # --- train a few steps ---
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                      total_steps=10)))
    opt = init_opt_state(params)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, batch=8, seq_len=64))
    for i in range(10):
        if cfg.input_kind == "embeds":
            x, y = pipe.next_embed_batch(cfg.d_model)
        else:
            x, y = pipe.next_batch()
        params, opt, m = step(params, opt, x, y)
        if i % 3 == 0:
            print(f"  step {i}: loss {float(m['loss']):.4f}")

    # --- serve it ---
    if cfg.encoder_only:
        print("encoder-only arch: no decode; done.")
        return
    eng = Engine(model, params, max_seq=96, n_slots=4,
                 knobs=EngineKnobs(max_batch=4))
    rng = np.random.default_rng(0)
    for i in range(6):
        eng.submit(Request(prompt=list(rng.integers(0, cfg.vocab_size, 12)),
                           max_new_tokens=8, customer=f"c{i % 2}"))
    stats = eng.run()
    print(f"served {len(stats.completed)} requests, "
          f"{stats.decode_tokens} decode tokens, "
          f"goodput {eng.goodput(ttft_slo=50, tbt_slo=5):.2f} tok/step")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "qwen3-1.7b")
