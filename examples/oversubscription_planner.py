"""Oversubscription planner (paper §4.4/§5.3): use the TAPAS simulator with
an estimated workload to size how many extra racks fit the existing
cooling/power envelopes.

    PYTHONPATH=src python examples/oversubscription_planner.py
"""
from repro.core.datacenter import DCConfig
from repro.core.oversubscribe import max_safe_oversubscription, sweep
from repro.core.simulator import BASELINE, TAPAS


def main() -> None:
    dc = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)
    rows = sweep([BASELINE, TAPAS], ratios=(0.0, 0.2, 0.4), dc=dc,
                 horizon_h=12.0, seed=1)
    print(f"{'oversub':>8}{'policy':<22}{'thermal%':>10}{'power%':>8}"
          f"{'unserved%':>10}")
    for r in rows:
        print(f"{r['oversub']:>8.0%}{r['policy']:<22}"
              f"{r['thermal_capped_pct']:>10.3f}{r['power_capped_pct']:>8.3f}"
              f"{r['unserved_pct']:>10.2f}")
    for pol in ("baseline", TAPAS.name):
        safe = max_safe_oversubscription(rows, pol)
        print(f"max safe oversubscription ({pol}): {safe:.0%}")


if __name__ == "__main__":
    main()
