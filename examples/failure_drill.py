"""Failure drill (paper §5.4, Table 2): UPS and AHU emergencies,
Baseline vs TAPAS.

    PYTHONPATH=src python examples/failure_drill.py
"""
from repro.core.datacenter import DCConfig
from repro.core.failures import run_drill
from repro.core.simulator import BASELINE, TAPAS


def main() -> None:
    dc = DCConfig(n_rows=4, racks_per_row=5, servers_per_rack=4)
    print(f"{'failure':<8}{'policy':<22}{'IaaS perf':>10}{'SaaS perf':>10}"
          f"{'quality':>9}")
    for kind in ("ups", "thermal"):
        for pol in (BASELINE, TAPAS):
            r = run_drill(kind, pol, dc=dc, seed=1, horizon_h=18.0)
            row = r.row()
            print(f"{kind:<8}{row['policy']:<22}"
                  f"{row['iaas_perf_pct']:>9.1f}%{row['saas_perf_pct']:>9.1f}%"
                  f"{row['quality_pct']:>8.1f}%")
    print("\nTAPAS absorbs the emergency by steering + reconfiguring SaaS "
          "(bounded quality cost) instead of uniform frequency caps.")


if __name__ == "__main__":
    main()
